"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kvh,sq,skv,d,window,local",
    [(2, 4, 2, 256, 256, 64, None, None),
     (1, 8, 8, 128, 128, 128, None, None),
     (2, 4, 1, 256, 256, 64, 64, None),
     (1, 4, 2, 384, 384, 64, None, 128),
     (2, 2, 2, 200, 200, 64, None, None),
     (1, 4, 4, 128, 384, 64, None, None)])
def test_flash_attention_vs_ref(b, h, kvh, sq, skv, d, window, local, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kvh, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kvh, skv, d), jnp.float32).astype(dtype)
    qo = skv - sq
    out = flash_attention_fwd(q, k, v, causal=True, window=window,
                              local_block=local, q_offset=qo, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window,
                        local_block=local, q_offset=qo)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kvh,s,d,t,window,local",
    [(2, 8, 2, 1024, 64, 1023, None, None),
     (2, 8, 8, 1024, 128, 700, None, None),
     (1, 4, 2, 512, 64, 2000, 512, None),
     (1, 4, 4, 256, 64, 900, None, 128),
     (2, 4, 2, 700, 64, 699, None, None)])
def test_flash_decode_vs_ref(b, h, kvh, s, d, t, window, local, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32).astype(dtype)
    out = flash_decode(q, kc, vc, t=t, window=window, local_block=local,
                       interpret=True)
    ref = decode_ref(q, kc, vc, t=t, window=window, local_block=local)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("b,h,t,kd", [(2, 4, 64, 64), (1, 2, 96, 64),
                                      (2, 2, 70, 64), (1, 1, 32, 128)])
def test_wkv6_vs_sequential_ref(b, h, t, kd):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, t, h, kd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, kd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, kd), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, kd), jnp.float32))
    u = jax.random.normal(ks[4], (h, kd), jnp.float32) * 0.5
    s0 = jax.random.normal(KEY, (b, h, kd, kd), jnp.float32)
    y, sf = wkv6(r, k, v, lw, u, s0)
    yr, sfr = wkv6_ref(jnp.moveaxis(r, 1, 2), jnp.moveaxis(k, 1, 2),
                       jnp.moveaxis(v, 1, 2), jnp.moveaxis(lw, 1, 2), u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(
        jnp.moveaxis(yr, 1, 2)), atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr),
                               atol=5e-3, rtol=5e-3)


def test_model_wkv_chunked_matches_kernel():
    """The model's jnp chunked WKV and the Pallas kernel agree."""
    from repro.models.rwkv6 import wkv6_chunked
    ks = jax.random.split(KEY, 5)
    b, t, h, kd = 2, 64, 2, 64
    r = jax.random.normal(ks[0], (b, t, h, kd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, kd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, kd), jnp.float32)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, kd), jnp.float32))
    u = jax.random.normal(ks[4], (h, kd), jnp.float32) * 0.5
    s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    y_model, s_model = wkv6_chunked(r, k, v, lw, u, s0)
    y_kern, s_kern = wkv6(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kern),
                               atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(s_model), np.asarray(s_kern),
                               atol=5e-3, rtol=5e-3)


def test_chunked_attention_oracle_matches_naive():
    """layers.chunked_attention (the model path) vs materialised softmax."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(KEY, 3)
    b, sq, h, kvh, d = 2, 160, 4, 2, 64
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kvh, d), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, chunk=48)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(ref, 1, 2)),
                               atol=2e-5, rtol=2e-5)
