"""Dominator tree + SLO distribution invariants (incl. DAGs w/ splits)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to the
    from _hypothesis_fallback import (   # vendored deterministic sampler
        given, settings, strategies as st)

from repro.core.dominator import (anl_labels, distribute_slo, dominator_tree,
                                  reduce_chain)
from repro.core.profiles import FunctionProfile, ProfileTable
from repro.core.workflows import PAPER_APPS, Workflow


def tables_for(wf: Workflow) -> dict:
    out = {}
    for i, f in enumerate(sorted({wf.func_of[s] for s in wf.stages})):
        fp = FunctionProfile(f, 100.0 * (i + 1), 1000.0, 1.0)
        out[f] = ProfileTable.build(fp, batches=(1, 2), vcpus=(1, 2),
                                    vgpus=(1, 2))
    return out


def diamond() -> Workflow:
    # a -> (b || c) -> d
    return Workflow(
        "diamond", ("a", "b", "c", "d"),
        {s: s for s in ("a", "b", "c", "d")},
        {"a": ("b", "c"), "b": ("d",), "c": ("d",), "d": ()})


def test_dominator_tree_pipeline():
    wf = PAPER_APPS["image_classification"]
    idom = dominator_tree(wf)
    stages = wf.stages
    assert idom[stages[0]] is None
    assert idom[stages[1]] == stages[0]
    assert idom[stages[2]] == stages[1]


def test_dominator_tree_diamond():
    wf = diamond()
    idom = dominator_tree(wf)
    assert idom["a"] is None
    assert idom["b"] == "a" and idom["c"] == "a"
    assert idom["d"] == "a"          # join dominated by the split, not b/c


def test_reduce_chain_diamond_parallel_anl():
    wf = diamond()
    anl = {"a": 0.1, "b": 0.3, "c": 0.2, "d": 0.4}
    chain = reduce_chain(wf, anl)
    # serialised: a, {b||c}, d
    assert [u.reduced for u in chain] == [False, True, False]
    assert chain[1].anl == pytest.approx(0.3)   # max branch sum


def test_anl_normalised():
    wf = PAPER_APPS["expanded_image_classification"]
    anl = anl_labels(wf, tables_for(wf))
    assert sum(anl.values()) == pytest.approx(1.0, abs=1e-6)
    assert all(v > 0 for v in anl.values())


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5))
def test_slo_fractions_sum_to_one_along_paths(group_size):
    for wf in list(PAPER_APPS.values()) + [diamond()]:
        groups = distribute_slo(wf, tables_for(wf), group_size)
        assert set(groups) == set(wf.stages)
        # walk every root->sink path; distinct groups on it sum to ~1
        def paths(s):
            succ = wf.edges.get(s, ())
            if not succ:
                return [[s]]
            return [[s] + p for t in succ for p in paths(t)]
        for root in wf.roots:
            for path in paths(root):
                seen, total = set(), 0.0
                for s in path:
                    g = groups[s]
                    if id(g) not in seen:
                        seen.add(id(g))
                        total += g.slo_fraction
                assert total == pytest.approx(1.0, abs=1e-6)


def test_group_size_bound():
    wf = PAPER_APPS["expanded_image_classification"]
    for g in (1, 2, 3):
        groups = distribute_slo(wf, tables_for(wf), g)
        for sg in {id(v): v for v in groups.values()}.values():
            assert len(sg.stages) <= g
