"""Minimal deterministic stand-in for ``hypothesis`` used when the real
package is not installed (the CI image pins it via requirements-dev.txt;
the bare runtime image does not ship it).

Only the surface these tests use is provided: ``@given`` over
``st.integers`` / ``st.floats`` strategies and ``@settings(max_examples,
deadline)``.  Examples are drawn from a fixed-seed RNG so runs are
reproducible; there is no shrinking — on failure the raw drawn values
appear in the assertion traceback.
"""
from __future__ import annotations

from types import SimpleNamespace

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


strategies = SimpleNamespace(integers=_integers, floats=_floats)


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # no functools.wraps: the wrapper must expose a zero-arg signature
        # or pytest tries to resolve the drawn params as fixtures
        def wrapper():
            n = getattr(wrapper, "_max_examples", 20)
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*[s.example(rng) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
