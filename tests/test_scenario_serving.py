"""Serving-subsystem tests: scenario engine determinism and shape,
autoscaler policy swapping, gateway shedding, telemetry accounting."""
import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.serving import (Gateway, Telemetry, format_table, get_autoscaler,
                           get_scenario)
from repro.serving.autoscaler import AUTOSCALERS, EwmaPrewarm, NoPrewarm
from repro.serving.traces import SCENARIOS

APPS = list(PAPER_APPS)


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


# ---------------------------------------------------------------------------
# scenario engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenarios_deterministic_under_seed(name):
    sc = get_scenario(name, app_names=APPS)
    a = sc.arrivals(APPS, 200, seed=42)
    b = get_scenario(name, app_names=APPS).arrivals(APPS, 200, seed=42)
    assert [(x.uid, x.t_ms, x.app) for x in a] == \
        [(x.uid, x.t_ms, x.app) for x in b]
    c = sc.arrivals(APPS, 200, seed=43)
    if name == "trace-replay":
        # a replayed trace is the same trace under every seed, by design
        assert [(x.t_ms, x.app) for x in a] == [(x.t_ms, x.app) for x in c]
    else:
        assert [(x.t_ms, x.app) for x in a] != [(x.t_ms, x.app) for x in c]


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenarios_monotone_and_positive(name):
    arr = get_scenario(name, app_names=APPS).arrivals(APPS, 300, seed=0)
    ts = np.array([a.t_ms for a in arr])
    assert np.all(np.diff(ts) > 0)
    assert ts[0] > 0
    assert all(a.app in APPS for a in arr)
    assert [a.uid for a in arr] == list(range(300))


def test_uniform_intervals_within_bounds():
    sc = get_scenario("uniform-normal")
    ts = np.array([a.t_ms for a in sc.arrivals(APPS, 500, seed=1)])
    gaps = np.diff(ts)
    assert gaps.min() >= 20.0 and gaps.max() <= 33.6


def test_heavy_tail_burstier_than_uniform():
    n = 2000
    tail = np.diff([a.t_ms for a in
                    get_scenario("azure-tail").arrivals(APPS, n, seed=2)])
    uni = np.diff([a.t_ms for a in
                   get_scenario("uniform-normal").arrivals(APPS, n, seed=2)])
    cv = lambda x: np.std(x) / np.mean(x)
    assert cv(tail) > 2 * cv(uni)


def test_mmpp_burstier_than_uniform():
    n = 2000
    mmpp = np.diff([a.t_ms for a in
                    get_scenario("mmpp").arrivals(APPS, n, seed=3)])
    uni = np.diff([a.t_ms for a in
                   get_scenario("uniform-normal").arrivals(APPS, n, seed=3)])
    cv = lambda x: np.std(x) / np.mean(x)
    assert cv(mmpp) > 2 * cv(uni)


def test_flash_crowd_spike_is_denser():
    sc = get_scenario("flash-crowd")
    arr = sc.arrivals(APPS, 1000, seed=4)
    gaps = np.diff([a.t_ms for a in arr])
    spike = [g for i, g in enumerate(gaps) if sc.in_spike(i + 1)]
    calm = [g for i, g in enumerate(gaps) if not sc.in_spike(i + 1)]
    assert np.mean(spike) < np.mean(calm) / 3


def test_diurnal_mean_rate_near_target():
    sc = get_scenario("diurnal", mean_interval_ms=30.0)
    ts = [a.t_ms for a in sc.arrivals(APPS, 3000, seed=5)]
    mean_gap = ts[-1] / len(ts)
    assert 15.0 < mean_gap < 60.0       # sinusoid-modulated, same order


def test_skewed_mix_weights_apply():
    sc = get_scenario("skewed-mix", app_names=APPS)
    arr = sc.arrivals(APPS, 2000, seed=6)
    hot = sum(1 for a in arr if a.app == APPS[0]) / len(arr)
    assert 0.7 < hot < 0.9


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        get_scenario("nope")
    with pytest.raises(KeyError):
        get_autoscaler("nope")


# ---------------------------------------------------------------------------
# autoscaler policies
# ---------------------------------------------------------------------------
def _run_serving(tables, autoscaler, n=50, seed=0, slo_mult=1.0,
                 scenario="flash-crowd", shed_doomed=True):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=seed,
                     autoscaler=autoscaler, count_overhead=False)
    gw = Gateway(sim, shed_doomed=shed_doomed)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    return gw.run(), sim


def test_autoscaler_registry_complete():
    assert {"none", "ewma", "finegrained"} <= set(AUTOSCALERS)


def test_policy_swap_changes_cold_starts(tables):
    tel_none, _ = _run_serving(tables, get_autoscaler("none"))
    tel_ewma, _ = _run_serving(tables, get_autoscaler("ewma"))
    tel_fine, _ = _run_serving(tables, get_autoscaler("finegrained"))
    # no-prewarm pays the most cold starts; the policies must actually
    # differ (the emulator no longer hard-codes one behaviour)
    assert tel_none.cold_starts > tel_ewma.cold_starts
    assert tel_none.cold_starts != tel_fine.cold_starts
    assert tel_ewma.cold_starts <= tel_fine.cold_starts + 5


def test_legacy_prewarm_flag_maps_to_policies(tables):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), prewarm=True)
    assert isinstance(sim.autoscaler, EwmaPrewarm)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), prewarm=False)
    assert isinstance(sim.autoscaler, NoPrewarm)


def test_finegrained_scales_pool_with_load(tables):
    # after a sustained burst the fine-grained policy must have grown the
    # warm pool beyond its minimal seed for at least one hot function
    pol = get_autoscaler("finegrained")
    _, sim = _run_serving(tables, pol, n=60, scenario="uniform-heavy")
    assert sim.cold_starts < 60 * 3     # pool absorbed most of the load
    assert any(len(ts) >= 2 for ts in pol._times.values())


# ---------------------------------------------------------------------------
# gateway + telemetry accounting
# ---------------------------------------------------------------------------
def test_telemetry_accounting_consistent(tables):
    n = 40
    tel, sim = _run_serving(tables, get_autoscaler("ewma"), n=n,
                            scenario="uniform-normal")
    s = tel.summary()
    assert s["injected"] == n
    assert s["injected"] == s["admitted"] + s["shed"]
    assert s["completed"] == s["admitted"]
    assert len(sim.shed) == s["shed"]
    # per-stage job counts: every admitted instance runs each pipeline
    # stage exactly once
    for app_name, app in PAPER_APPS.items():
        admitted = tel.admitted[app_name]
        for stage in app.stages:
            st = tel.stage.get((app_name, stage))
            got = st.jobs if st else 0
            assert got == admitted, (app_name, stage)
    # histograms saw one end-to-end sample per completion
    assert tel.e2e.n == s["completed"]
    assert 0.0 <= s["utilization"] <= 1.0
    assert s["slo_attainment"] <= 1.0


def test_gateway_sheds_doomed_requests(tables):
    # SLO far below the fastest possible path => everything is doomed
    tel, sim = _run_serving(tables, get_autoscaler("ewma"), n=30,
                            slo_mult=0.01, scenario="uniform-heavy")
    s = tel.summary()
    assert s["shed"] == 30
    assert s["completed"] == 0
    assert sim.tasks == []              # no GPU time wasted on doomed work
    # same workload without shedding burns resources on guaranteed misses
    tel2, sim2 = _run_serving(tables, get_autoscaler("ewma"), n=30,
                              slo_mult=0.01, scenario="uniform-heavy",
                              shed_doomed=False)
    assert tel2.summary()["shed"] == 0
    assert len(sim2.tasks) > 0


def test_serving_run_deterministic(tables):
    a, _ = _run_serving(tables, get_autoscaler("ewma"), n=40, seed=9)
    b, _ = _run_serving(tables, get_autoscaler("ewma"), n=40, seed=9)
    assert a.summary() == b.summary()


def test_format_table_renders_all_rows(tables):
    tel, _ = _run_serving(tables, get_autoscaler("ewma"), n=20)
    tel.scenario = "flash-crowd"
    txt = format_table([tel.summary()])
    assert "flash-crowd" in txt and "ESG" in txt and "ewma" in txt
    assert len(txt.splitlines()) == 3   # header, rule, one row
