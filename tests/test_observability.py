"""Flight-recorder test suite (PR 6).

Four layers of protection around ``repro.obs``:

  * **invisibility** — every serving scenario replays bit-identically
    with the recorder enabled vs absent: observing a run must never
    change a placement, a price or a shed decision;
  * **golden trace** — a tiny deterministic run's Perfetto export is
    pinned byte-for-byte (parsed-JSON equality) against a committed
    fixture and schema-validated (ph/ts/dur/pid/tid, one complete span
    per lifecycle phase, stage spans nested in their request envelope);
  * **audit** — plan records carry the cache regime and search effort,
    dispatch/completion back-fill predicted-vs-realized latencies, the
    calibration block surfaces through ``Telemetry.summary()``, and the
    event-sparse emulator's skips are logged with their certificate;
  * **telemetry edge cases** — empty/single-bucket histogram
    percentiles, histogram merge ≡ recording the union (property test),
    shed precision with zero scorable sheds, attainment with zero
    injected, and ``format_table`` rendering of None metrics.
"""
import json
import pathlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to the
    from _hypothesis_fallback import (   # vendored deterministic sampler
        given, settings, strategies as st)

from repro.cluster.emulator import ClusterSim
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.obs import (NULL_RECORDER, AuditLog, MetricsBus, PlanRecord,
                       Recorder, SpanTracer)
from repro.obs.validate import (validate_metrics, validate_nesting,
                                validate_trace)
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.telemetry import (LatencyHistogram, Telemetry,
                                     format_table)
from repro.serving.traces import SCENARIOS

APPS = list(PAPER_APPS)
HERE = pathlib.Path(__file__).resolve().parent
GOLDEN = HERE / "fixtures" / "golden_trace_mmpp_n6.json"
N_REQ = 24


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _run(tables, scenario, n=N_REQ, seed=0, slo_mult=1.0, recorder=None,
         placement="locality", autoscaler="ewma", shed=True, **sim_kw):
    sched = ESGScheduler(PAPER_APPS, tables, placement=placement)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler(autoscaler),
                     recorder=recorder, **sim_kw)
    gw = Gateway(sim, shed_doomed=shed)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    return tel, sim


def _timeline(sim):
    tasks = [(t.start_ms, t.end_ms, t.exec_start_ms, t.invoker, t.stage,
              t.func, t.config, t.tier, t.cold, t.cost, t.quota_slices,
              t.penalty_ms, t.full_penalty_ms)
             for t in sim.tasks]
    done = [(i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed]
    shed = [i.uid for i in sim.shed]
    return tasks, done, shed, sim.total_cost, sim.cold_starts, \
        sim.remote_transfers


# ---------------------------------------------------------------------------
# invisibility: the recorder never changes a run
# ---------------------------------------------------------------------------
def test_default_recorder_is_the_shared_null_object(tables):
    sched = ESGScheduler(PAPER_APPS, tables)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched, seed=0)
    assert sim.recorder is NULL_RECORDER
    assert not sim.recorder.enabled


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_recorder_replays_every_scenario_bit_identically(tables, scenario):
    tel_off, sim_off = _run(tables, scenario)
    tel_on, sim_on = _run(tables, scenario, recorder=Recorder())
    assert _timeline(sim_on) == _timeline(sim_off)
    assert sim_on.slo_hit_rate() == sim_off.slo_hit_rate()
    s_on, s_off = tel_on.summary(), tel_off.summary()
    # the only summary difference the recorder may make is *adding* the
    # calibration block it alone can compute
    s_on.pop("predicted_vs_realized")
    s_off.pop("predicted_vs_realized")
    assert s_on == s_off


def test_recorder_invisible_under_memory_pressure_and_overlap(tables):
    kw = dict(n=40, hbm_per_vgpu_mb=256.0, shared_weights=True,
              overlap=True, prefetch=True, placement="memory")
    _, sim_off = _run(tables, "mmpp", **kw)
    rec = Recorder()
    _, sim_on = _run(tables, "mmpp", recorder=rec, **kw)
    assert _timeline(sim_on) == _timeline(sim_off)
    # the congested config exercises the device tracks: PCIe copies and
    # HBM demotions land on per-device pids
    doc = {"displayTimeUnit": "ms", "traceEvents": rec.tracer.events()}
    cats = validate_trace(doc, required=("request", "queue", "exec",
                                         "pcie"))
    validate_nesting(doc)
    assert cats["pcie"] > 0
    assert any(e["ph"] == "i" and e["cat"] == "hbm"
               for e in doc["traceEvents"])
    assert rec.metrics.total("demotions") > 0
    assert rec.metrics.total("xfer_demand_ms") > 0


# ---------------------------------------------------------------------------
# golden Perfetto trace
# ---------------------------------------------------------------------------
def _golden_doc(tables, tmp_path):
    rec = Recorder()
    _run(tables, "mmpp", n=6, recorder=rec)
    path = tmp_path / "trace.json"
    return rec.export(str(path), None, None), \
        json.loads(path.read_text())


def test_golden_trace_fixture_matches_and_validates(tables, tmp_path):
    written, doc = _golden_doc(tables, tmp_path)
    assert written == {"trace": str(tmp_path / "trace.json")}
    cats = validate_trace(doc)
    validate_nesting(doc)
    assert cats["request"] == 6
    assert doc["displayTimeUnit"] == "ms"
    golden = json.loads(GOLDEN.read_text())
    assert doc == golden, (
        "exported trace drifted from the committed golden fixture; "
        "if the change is intentional regenerate it with "
        "tests/test_observability.py::_golden_doc")


def test_trace_lanes_never_overlap(tables, tmp_path):
    _, doc = _golden_doc(tables, tmp_path)
    lanes: dict[tuple, list[tuple[float, float]]] = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X" and e["cat"] != "request":
            lanes.setdefault((e["pid"], e["tid"]), []).append(
                (e["ts"], e["ts"] + e["dur"]))
    for spans in lanes.values():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-6, "slices overlap on one lane"


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({})
    with pytest.raises(ValueError, match="missing dur"):
        validate_trace({"traceEvents": [
            {"ph": "X", "ts": 0, "pid": 1, "tid": 0, "cat": "request"}]})
    with pytest.raises(ValueError, match="lifecycle"):
        validate_trace({"traceEvents": []})
    ok = {"traceEvents": [
        {"ph": "X", "ts": 0.0, "dur": 1.0, "pid": 10_000, "tid": 0,
         "cat": c, "name": c} for c in ("request", "queue", "exec")]}
    assert validate_trace(ok) == {"request": 1, "queue": 1, "exec": 1}
    bad = {"traceEvents": ok["traceEvents"] + [
        {"ph": "X", "ts": 50.0, "dur": 1.0, "pid": 10_000, "tid": 1,
         "cat": "exec", "name": "escape"}]}
    with pytest.raises(ValueError, match="escapes"):
        validate_nesting(bad)


def test_tracer_end_request_is_idempotent():
    tr = SpanTracer()
    tr.begin_request(7, "a", 0.0)
    tr.end_request(7, 10.0, 100.0)
    tr.end_request(7, 12.0, 100.0)       # multi-sink DAG second completion
    spans = [e for e in tr.events() if e["ph"] == "X"]
    assert len(spans) == 1 and spans[0]["dur"] == 10.0 * 1e3


# ---------------------------------------------------------------------------
# planner decision audit
# ---------------------------------------------------------------------------
def test_audit_records_regimes_and_calibration(tables, tmp_path):
    rec = Recorder()
    tel, sim = _run(tables, "mmpp", recorder=rec)
    audit = rec.audit
    assert len(audit.plans) == len(sim.tasks) >= 1
    regimes = audit.regimes()
    assert set(regimes) <= {"floor", "budget-free", "exact", "miss",
                            "nocache", "sunk"}
    assert regimes.get("miss", 0) > 0    # cold caches always miss first
    # every dispatched plan was back-filled at completion
    filled = [p for p in audit.plans if p.task_tid is not None]
    assert filled and all(p.predicted_ms is not None
                          and p.realized_ms is not None for p in filled)
    cal = audit.calibration()
    assert cal["n"] == len(filled) > 0
    assert cal["p90_abs_err"] >= 0.0
    assert all(v["n"] > 0 for v in cal["per_stage"].values())
    # the same block surfaces through the run telemetry
    assert tel.summary()["predicted_vs_realized"]["n"] == cal["n"]
    # JSONL export: one parseable typed record per line
    path = tmp_path / "audit.jsonl"
    n = audit.export_jsonl(str(path))
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == n == len(audit.plans) + len(audit.skips)
    assert all(r["type"] in ("plan", "skip") for r in lines)


def test_audit_logs_sparse_skips_with_certificates(tables):
    # the config test_planner_fastpath pins for sparse_skips > 0: a
    # flash crowd on a starved fleet with wide slack and no shedding
    rec = Recorder()
    _, sim = _run(tables, "flash-crowd", n=100, slo_mult=8.0, shed=False,
                  n_invokers=2, recorder=rec)
    assert sim.sparse_skips > 0
    assert len(rec.audit.skips) == sim.sparse_skips
    assert all(s.certificate for s in rec.audit.skips)
    assert rec.metrics.total("sparse_skips") == sim.sparse_skips


def test_audit_unit_lifecycle():
    audit = AuditLog()
    rec = PlanRecord(t_ms=1.0, app="a", stage="s", n_jobs=2, g_slo_ms=100.0,
                     regime="miss", expansions=5, pruned_time=1,
                     pruned_cost=2, est_time_ms=80.0, est_job_cost=0.5,
                     slack_ms=20.0, n_candidates=3)
    audit.on_plan(rec)
    audit.on_dispatch("a", "s", tid=42, config="c", predicted_ms=80.0)
    audit.on_complete(42, realized_ms=88.0)
    assert rec.task_tid == 42 and rec.realized_ms == 88.0
    cal = audit.calibration()
    assert cal["n"] == 1
    assert cal["p50_err"] == pytest.approx(0.1)
    # unmatched dispatches and completions are ignored, not errors
    audit.on_dispatch("a", "other", tid=7, config="c", predicted_ms=1.0)
    audit.on_complete(999, 5.0)
    assert audit.calibration()["n"] == 1
    assert AuditLog().calibration() == {
        "n": 0, "mean_err": 0.0, "mean_abs_err": 0.0, "p50_err": 0.0,
        "p90_abs_err": 0.0, "per_stage": {}}


# ---------------------------------------------------------------------------
# metrics bus
# ---------------------------------------------------------------------------
def test_metrics_bus_windows_and_kinds(tmp_path):
    m = MetricsBus(window_ms=100.0)
    m.inc("c", 10.0)
    m.inc("c", 99.0, 2.0)
    m.inc("c", 150.0)
    m.gauge("g", 10.0, 5.0)
    m.gauge("g", 20.0, 7.0)              # same window: last value wins
    m.observe("h", 50.0, 3.0)
    m.observe("h", 60.0, 9.0)
    assert m.points("c") == [(0.0, 3.0), (100.0, 1.0)]
    assert m.total("c") == 4.0
    assert m.points("g") == [(0.0, 7.0)]
    assert m.points("h") == [(0.0, [2, 12.0, 3.0, 9.0])]
    assert m.rate_per_s("c") == pytest.approx(4.0 / 0.2)
    with pytest.raises(ValueError, match="is a counter"):
        m.gauge("c", 0.0, 1.0)
    with pytest.raises(ValueError, match="not a counter"):
        m.total("g")
    with pytest.raises(ValueError, match="positive"):
        MetricsBus(window_ms=0.0)
    doc = m.to_json(str(tmp_path / "m.json"))
    assert validate_metrics(doc) == 3
    m.to_csv(str(tmp_path / "m.csv"))
    rows = (tmp_path / "m.csv").read_text().splitlines()
    assert rows[0].startswith("series,kind,window_start_ms")
    assert len(rows) == 1 + 2 + 1 + 1    # header + c windows + g + h


def test_recorder_exports_all_three_artifacts(tables, tmp_path):
    rec = Recorder()
    _run(tables, "mmpp", recorder=rec)
    out = rec.export(str(tmp_path / "t.json"), str(tmp_path / "m.csv"),
                     str(tmp_path / "a.jsonl"))
    assert set(out) == {"trace", "metrics", "audit"}
    validate_trace(json.loads((tmp_path / "t.json").read_text()))
    assert (tmp_path / "m.csv").read_text().startswith("series,")
    assert (tmp_path / "a.jsonl").read_text().strip()
    # metrics carry the headline serving series
    names = set(rec.metrics.series)
    assert {"tasks", "jobs", "plans", "queue_wait_ms", "exec_ms",
            "queue_depth", "slice_util", "hbm_used_mb",
            "admitted"} <= names
    assert rec.metrics.total("tasks") == len(_run(tables, "mmpp")[1].tasks)


# ---------------------------------------------------------------------------
# telemetry edge cases (satellites)
# ---------------------------------------------------------------------------
def test_histogram_empty_and_single_bucket_percentiles():
    h = LatencyHistogram()
    assert h.percentile(50) == 0.0 and h.mean == 0.0
    assert h.to_dict()["n"] == 0
    h.record(5.0)
    idx = int(np.searchsorted(h.bounds, 5.0, side="right"))
    lo, hi = h.bounds[idx - 1], h.bounds[idx]
    for p in (50.0, 100.0):
        assert lo <= h.percentile(p) <= hi
    assert 0.0 <= h.percentile(0.0) <= hi   # rank 0: underflow edge
    assert h.mean == 5.0 and h.max_ms == 5.0


def test_histogram_cumsum_cache_invalidated_by_record():
    h = LatencyHistogram()
    h.record(10.0)
    p95_before = h.percentile(95)
    assert h._cum is not None            # cached by the percentile call
    h.record(10_000.0)
    assert h._cum is None                # record() must invalidate
    assert h.percentile(95) > p95_before


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 60), st.integers(0, 60), st.integers(0, 2 ** 16))
def test_histogram_merge_equals_recording_the_union(n_a, n_b, seed):
    rng = np.random.default_rng(seed)
    xs = list(10 ** rng.uniform(-1, 6, n_a))
    ys = list(10 ** rng.uniform(-1, 6, n_b))
    a, b, ref = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
    for v in xs:
        a.record(v)
        ref.record(v)
    for v in ys:
        b.record(v)
        ref.record(v)
    out = a.merge(b)
    assert out is a
    assert np.array_equal(a.counts, ref.counts)
    assert a.n == ref.n and a.max_ms == ref.max_ms
    assert a.total == pytest.approx(ref.total)
    for p in (0, 25, 50, 90, 99, 100):
        assert a.percentile(p) == ref.percentile(p)


def test_histogram_merge_rejects_layout_mismatch():
    with pytest.raises(ValueError, match="bucket layouts"):
        LatencyHistogram().merge(LatencyHistogram(buckets_per_decade=4))


def test_shed_precision_none_with_zero_scorable_sheds():
    tel = Telemetry()
    assert tel.shed_precision() is None
    tel.on_shed("app")                   # counted but not scorable
    assert tel.shed_precision() is None
    assert tel.summary()["shed_precision"] is None


def test_slo_attainment_with_zero_injected():
    tel = Telemetry()
    assert tel.slo_attainment() == 0.0
    assert tel.cost_per_1k() == 0.0
    assert tel.summary()["slo_attainment"] == 0.0


def test_format_table_renders_none_as_dash():
    row = Telemetry().summary()
    row["scenario"] = "empty"
    out = format_table([row], extra_cols=[
        ("shed_precision", "shed_prec", "{:.2f}"),
        ("prefetch_hit_rate", "pf_hit", "{:.2f}"),
        ("missing_key", "mk", "{:.1f}")])
    line = out.splitlines()[2]
    assert line.split()[-3:] == ["-", "-", "-"]
    assert "None" not in out
