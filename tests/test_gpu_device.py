"""Shareable-GPU device-model invariants.

Property-style coverage of ``repro.gpu.DeviceModel`` (random
alloc/resize/release/swap walks never oversubscribe slices or HBM), the
fractional-quota latency model, vertical resizing of running tasks in
the emulator (including a full slice-timeline replay), the two-tier
warm-state swap path under finite HBM, the gateway's per-stage
queueing-delay EWMA + shed precision, and the trace-replay scenario.
"""
import numpy as np
import pytest

from repro.cluster.emulator import AppInstance, ClusterSim
from repro.core.profiles import PAPER_FUNCTIONS, Config, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.gpu import (COLD, HOT, WARM, DeviceModel, OversubscribedError,
                       SLICES_PER_VGPU, swap_in_ms)
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.traces import TraceReplayScenario

APPS = list(PAPER_APPS)


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


# ---------------------------------------------------------------------------
# device model: random-walk invariants
# ---------------------------------------------------------------------------
def test_device_random_walk_never_oversubscribes():
    """600 random alloc/resize/release/prewarm/gc steps: the slice and
    HBM ledgers must stay consistent and within capacity throughout
    (``check()`` raises OversubscribedError on any violation)."""
    rng = np.random.default_rng(0)
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=512.0)   # 16 slices, 2 GB
    funcs = [("a", 300.0), ("b", 700.0), ("c", 150.0), ("d", 0.0)]
    now, live = 0.0, []
    for _ in range(600):
        now += float(rng.uniform(0.0, 50.0))
        op = int(rng.integers(5))
        f, mb = funcs[int(rng.integers(len(funcs)))]
        if op == 0:
            sl = int(rng.integers(1, 9))
            if dev.fits(sl, mb, f, now):
                alloc, tier = dev.start(f, sl, mb, now)   # must not raise
                assert tier in (HOT, WARM, COLD)
                live.append(alloc)
        elif op == 1 and live:
            a = live[int(rng.integers(len(live)))]
            dev.resize(a.aid, int(rng.integers(1, 17)))   # False ok, no drift
        elif op == 2 and live:
            a = live.pop(int(rng.integers(len(live))))
            dev.stop(a.aid, now + float(rng.uniform(100.0, 5000.0)))
        elif op == 3:
            dev.add_warm(f, now + float(rng.uniform(100.0, 5000.0)), mb, now)
        else:
            dev._gc(now)
        dev.check()
        assert 0 <= dev.used_slices <= dev.total_slices
        assert dev.hbm_used_mb <= dev.hbm_total_mb + 1e-6
    for a in live:
        dev.stop(a.aid, now + 100.0)
    assert dev.used_slices == 0


def test_device_rejects_oversubscription():
    dev = DeviceModel(vgpus=1)                            # 4 slices
    a, _ = dev.start("f", 3, 0.0, 0.0)
    assert not dev.resize(a.aid, 6)                       # only 1 slice free
    assert dev.resize(a.aid, 4)
    with pytest.raises(OversubscribedError):
        dev.start("g", 1, 0.0, 0.0)
    assert not dev.resize(a.aid, 0)                       # below MIN_SLICES
    dev.stop(a.aid, 10.0)
    assert dev.used_slices == 0


def test_swap_tiers_demotion_and_hits():
    """hot -> (pressure) -> warm -> swap-in, with stats to match."""
    dev = DeviceModel(vgpus=1, hbm_per_vgpu_mb=1000.0)
    a1, t1 = dev.start("f", 1, 600.0, 0.0)
    assert t1 == COLD
    dev.stop(a1.aid, 1e6)                  # f idles hot: 600 MB resident
    a2, t2 = dev.start("g", 1, 600.0, 1.0)
    assert t2 == COLD and dev.stats.demotions == 1   # f demoted to host
    dev.stop(a2.aid, 1e6)                  # g idles hot now
    a3, t3 = dev.start("f", 1, 600.0, 2.0)
    assert t3 == WARM                      # container survived, weights didn't
    assert dev.stats.swap_ins == 1 and dev.stats.demotions == 2
    assert dev.stats.swap_in_ms == pytest.approx(swap_in_ms(600.0))
    dev.stop(a3.aid, 1e6)
    a4, t4 = dev.start("f", 1, 600.0, 3.0)
    assert t4 == HOT                       # weights still resident: free start
    assert dev.stats.hot_hits == 1


def test_unbounded_hbm_keeps_everything_hot():
    dev = DeviceModel(vgpus=2)             # hbm_per_vgpu_mb=None: unbounded
    for i in range(20):
        dev.add_warm("f", 1e6, 4000.0, 0.0)
    a, tier = dev.start("f", 1, 4000.0, 1.0)
    assert tier == HOT and dev.stats.demotions == 0


# ---------------------------------------------------------------------------
# fractional-quota latency model
# ---------------------------------------------------------------------------
def test_quota_model_monotone():
    fp = PAPER_FUNCTIONS["segmentation"]
    c = Config(4, 2, 2)
    assert fp.exec_ms(c, quota_vgpu=2.0) == fp.exec_ms(c)
    assert fp.exec_ms(c, quota_vgpu=1.0) > fp.exec_ms(c)      # throttled
    assert fp.exec_ms(c, quota_vgpu=0.5) > fp.exec_ms(c, quota_vgpu=1.0)
    assert fp.exec_ms(c, quota_vgpu=4.0) < fp.exec_ms(c)      # surplus


def test_resize_task_changes_end_time_and_cost(tables):
    """Shrinking a running task's quota must push its completion out per
    the quota model; growing pulls it back in; billing follows."""
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=0,
                     autoscaler=get_autoscaler("none"), count_overhead=False)
    inst = AppInstance(PAPER_APPS[APPS[0]], 0, 0.0, 1e9)
    sim._on_arrival(inst)
    sim._schedule_pass()
    task = sim.tasks[0]
    assert task.tid in sim.running
    q0, e0 = task.quota_slices, task.end_ms
    assert q0 == task.config.vgpu * SLICES_PER_VGPU
    sim.now = (task.exec_start_ms + task.end_ms) / 2.0

    assert sim.resize_task(task, max(1, q0 // 2))
    e_shrunk = task.end_ms
    assert e_shrunk > e0                       # throttled: finishes later
    assert sim.total_cost == pytest.approx(sum(t.cost for t in sim.tasks))

    assert sim.resize_task(task, q0)           # restore the original quota
    assert sim.now < task.end_ms < e_shrunk    # speeds back up
    assert sim.resizes[0][3:] == (q0, max(1, q0 // 2))
    assert not sim.resize_task(task, task.quota_slices)   # no-op target


# ---------------------------------------------------------------------------
# emulator-level: vertical scaling never oversubscribes
# ---------------------------------------------------------------------------
def _serve(tables, scaler, scenario="flash-crowd", n=60, seed=0,
           slo_mult=1.0, hbm_mb=1024.0):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=seed,
                     autoscaler=get_autoscaler(scaler), count_overhead=False,
                     hbm_per_vgpu_mb=hbm_mb)
    gw = Gateway(sim)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    return gw.run(), sim, gw


def test_vertical_slice_timeline_replay(tables):
    """Replay every allocation, resize and release of a vertical run:
    per-invoker concurrent slice usage must never exceed capacity and no
    task's quota may drop below one slice."""
    _, sim, _ = _serve(tables, "vertical", n=80)
    assert sim.resizes, "vertical policy never resized a running pool"
    # events: (time, priority) with releases before resizes before allocs
    # at equal timestamps — the emulator's in-event ordering
    events = []
    for t in sim.tasks:
        # dispatched at the config quota; released at the (possibly
        # resized) final quota — the resize deltas bridge the two
        events.append((t.dispatch_ms, 2, t.invoker,
                       t.config.vgpu * SLICES_PER_VGPU))
        events.append((t.end_ms, 0, t.invoker, -t.quota_slices))
    quota_now = {}
    for when, inv, tid, old, new in sim.resizes:
        events.append((when, 1, inv, new - old))
        assert new >= 1
        quota_now[tid] = new
    # final quotas recorded on tasks must match the last resize
    for t in sim.tasks:
        if t.tid in quota_now:
            assert t.quota_slices == quota_now[t.tid]
    events.sort(key=lambda e: (e[0], e[1]))
    use = {i: 0 for i in range(len(sim.invokers))}
    cap = sim.invokers[0].vgpus * SLICES_PER_VGPU
    for _, _, inv, delta in events:
        use[inv] += delta
        assert 0 <= use[inv] <= cap, f"invoker {inv} at {use[inv]}/{cap}"
    assert all(u == 0 for u in use.values())
    # devices fully drained; warm pools are the only residents left
    for inv in sim.invokers:
        assert inv.device.used_slices == 0
        inv.device.check()


def test_vertical_beats_container_granularity(tables):
    """The acceptance bar: fractional vertical scaling beats
    container-granularity scaling on a PR-1 scenario (flash-crowd) —
    here on *both* SLO attainment and $-cost."""
    tel_frac, sim_frac, _ = _serve(tables, "vertical")
    tel_cont, _, _ = _serve(tables, "finegrained")
    assert sim_frac.gpu_summary()["resizes_up"] > 0
    assert tel_frac.slo_attainment() >= tel_cont.slo_attainment()
    assert tel_frac.cost_per_1k() < tel_cont.cost_per_1k()
    better_slo = tel_frac.slo_attainment() > tel_cont.slo_attainment()
    cheaper = tel_frac.cost_per_1k() < tel_cont.cost_per_1k()
    assert better_slo or cheaper


def test_finite_hbm_forces_swaps_but_completes(tables):
    """Tiny HBM: the run must survive on the warm/host tier (swap-ins,
    demotions) and still complete everything it admitted."""
    tel, sim, _ = _serve(tables, "ewma", scenario="uniform-heavy", n=50,
                         hbm_mb=256.0)
    g = sim.gpu_summary()
    assert g["swap_ins"] > 0 and g["demotions"] > 0
    assert tel.completed == tel.n_admitted
    assert g["hbm_peak_mb"] <= 256.0 * sim.invokers[0].vgpus + 1e-6
    # determinism with the device model in the loop
    tel2, _, _ = _serve(tables, "ewma", scenario="uniform-heavy", n=50,
                        hbm_mb=256.0)
    assert tel.summary() == tel2.summary()


# ---------------------------------------------------------------------------
# gateway: per-stage queueing-delay EWMA + shed precision
# ---------------------------------------------------------------------------
def test_gateway_qdelay_ewma_feeds_admission(tables):
    tel, sim, gw = _serve(tables, "ewma", scenario="uniform-heavy", n=60)
    gw.predicted_queueing_ms(sim.apps[APPS[0]])     # force a final ingest
    assert gw._qdelay, "no per-stage queueing delays observed"
    assert all(v >= 0.0 for v in gw._qdelay.values())
    # every (app, stage) key the EWMA saw belongs to a real stage
    for (app_name, stage) in gw._qdelay:
        assert stage in PAPER_APPS[app_name].stages


def test_shed_precision_all_true_when_provably_doomed(tables):
    tel, sim, _ = _serve(tables, "ewma", n=30, slo_mult=0.01)
    s = tel.summary()
    assert s["shed"] == 30 and s["completed"] == 0
    # budget below the empty-cluster fastest path: every shed is a true shed
    assert s["shed_true"] == 30 and s["shed_false"] == 0
    assert s["shed_precision"] == 1.0


def test_shed_precision_accounting_consistent(tables):
    tel, _, _ = _serve(tables, "ewma", scenario="flash-crowd", n=120,
                       slo_mult=0.9)
    s = tel.summary()
    assert len(tel.shed_records) == s["shed"]
    assert s["shed_true"] + s["shed_false"] + s["shed_unknown"] == s["shed"]
    if s["shed_true"] + s["shed_false"]:
        assert 0.0 <= s["shed_precision"] <= 1.0
    else:
        assert s["shed_precision"] is None


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------
def test_trace_replay_csv_roundtrip(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("t_ms,app\n10,%s\n30,unknown-fn\n70,%s\n"
                 % (APPS[1], APPS[1]))
    sc = TraceReplayScenario(csv_path=str(p))
    arr = sc.arrivals(APPS, 3, seed=0)
    assert [a.t_ms for a in arr] == [10.0, 30.0, 70.0]
    assert arr[0].app == APPS[1] and arr[2].app == APPS[1]
    assert arr[1].app in APPS                  # unknown fn remapped

    # wrap-around keeps time strictly increasing and repeats the shape
    arr9 = sc.arrivals(APPS, 9, seed=0)
    ts = [a.t_ms for a in arr9]
    assert len(arr9) == 9 and all(b > a for a, b in zip(ts, ts[1:]))


def test_trace_replay_rejects_bad_csv(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("time,function\n1,f\n")
    with pytest.raises(ValueError):
        TraceReplayScenario(csv_path=str(p))


def test_sample_azure_trace_ships_and_serves(tables):
    import pathlib
    csv = pathlib.Path(__file__).resolve().parents[1] / \
        "benchmarks" / "traces" / "sample_azure.csv"
    assert csv.exists()
    sc = TraceReplayScenario(csv_path=str(csv))
    assert len(sc.rows) >= 100
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=0,
                     count_overhead=False)
    gw = Gateway(sim)
    gw.inject(sc, 40, seed=1, slo_mult=1.2)
    tel = gw.run()
    assert tel.completed + tel.n_shed == 40
