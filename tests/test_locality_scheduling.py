"""Differential/property harness for weight-locality-aware scheduling.

Locks PR 3's three-layer change (shared-weights HBM ledger, memory-aware
placement, swap-priced planning) against the PR-2 baseline:

  * **differential replay** — every scenario in ``serving.traces`` runs
    under memory-blind vs memory-aware placement with identical seeds;
    memory-aware must never swap more and must hold SLO attainment on
    the seed settings, and with ``shared_weights=False`` +
    ``hbm_per_vgpu_mb=None`` the *event timeline* must be bit-identical
    to ``placement="locality"`` (legacy configs can't drift);
  * **property walks** — random attach/detach/resize/demote sequences
    on the refcounted shared-weights ledger never leak HBM, never
    double-charge a function, and keep every slice/HBM/refcount
    invariant mid-walk;
  * **golden regression** — one fig6 cell (mmpp scenario, default ESG
    policy) is pinned to a checked-in fixture so refactors of
    ``_place`` cannot silently shift legacy numbers;
  * **planner pricing** — ``esg_1q(penalties_ms=...)`` agrees with the
    brute-force oracle and degrades to the unpriced search at zero;
  * **trace CSV robustness** — blank/trailing lines are skipped and
    malformed rows raise a ``ValueError`` naming file and line.
"""
import json
import pathlib
import sys

import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.core.astar import brute_force, esg_1q
from repro.core.profiles import PAPER_FUNCTIONS, Config, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.gpu import (COLD, HOT, WARM, DeviceModel, OversubscribedError,
                       swap_in_ms, tier_penalty_ms)
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.traces import SCENARIOS, TraceReplayScenario

APPS = list(PAPER_APPS)
HERE = pathlib.Path(__file__).resolve().parent
HBM_MB = 512.0          # finite HBM: weight residency is a real constraint
N_REQ = 30              # per-scenario replay length (keeps the suite fast)


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _run(tables, scenario, placement, shared, hbm, n=N_REQ, seed=0,
         slo_mult=1.0):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables, placement=placement),
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"),
                     hbm_per_vgpu_mb=hbm, shared_weights=shared)
    gw = Gateway(sim)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    return tel, sim


def _timeline(sim):
    """Every observable event of a run: the full task stream plus the
    completion record — if any placement, tier, price or quota differs,
    so does this."""
    tasks = [(t.start_ms, t.end_ms, t.exec_start_ms, t.invoker, t.stage,
              t.func, t.config, t.tier, t.cold, t.cost, t.quota_slices)
             for t in sim.tasks]
    done = [(i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed]
    return tasks, done, sim.total_cost, sim.cold_starts, sim.remote_transfers


# ---------------------------------------------------------------------------
# differential replay over the full scenario catalogue
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_memory_mode_bit_identical_on_legacy_config(scenario, tables):
    """(c) With per-container weights and unbounded HBM there is nothing
    for memory awareness to exploit: placement='memory' must replay the
    exact event timeline of placement='locality'."""
    tel_mem, sim_mem = _run(tables, scenario, "memory", shared=False,
                            hbm=None)
    tel_loc, sim_loc = _run(tables, scenario, "locality", shared=False,
                            hbm=None)
    assert _timeline(sim_mem) == _timeline(sim_loc)
    # telemetry (not sim.summary(): that folds measured wall time into
    # mean_sched_overhead_ms, which is never bit-stable)
    assert tel_mem.summary() == tel_loc.summary()


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_memory_aware_never_swaps_more_and_holds_slo(scenario, tables):
    """(a)+(b) Under finite HBM, memory-aware placement with shared
    read-only weights must not increase swap-ins and must hold the SLO
    hit rate on the seed settings."""
    tel_b, sim_b = _run(tables, scenario, "locality", shared=False,
                        hbm=HBM_MB)
    tel_m, sim_m = _run(tables, scenario, "memory", shared=True, hbm=HBM_MB)
    gb, gm = sim_b.gpu_summary(), sim_m.gpu_summary()
    assert gm["swap_ins"] <= gb["swap_ins"]
    assert gm["demotions"] <= gb["demotions"]
    assert tel_m.slo_attainment() >= tel_b.slo_attainment()
    # both runs served everything they admitted
    assert tel_m.completed == tel_m.n_admitted


def test_memory_aware_strictly_wins_under_pressure(tables):
    """The acceptance bar, pinned on one bursty scenario: strictly fewer
    swap-ins AND better SLO or $-cost than the memory-blind baseline."""
    tel_b, sim_b = _run(tables, "mmpp", "locality", shared=False, hbm=HBM_MB)
    tel_m, sim_m = _run(tables, "mmpp", "memory", shared=True, hbm=HBM_MB)
    assert sim_b.gpu_summary()["swap_ins"] > 0, "baseline not under pressure"
    assert sim_m.gpu_summary()["swap_ins"] < sim_b.gpu_summary()["swap_ins"]
    assert sim_m.gpu_summary()["shared_hits"] > 0
    better_slo = tel_m.slo_attainment() > tel_b.slo_attainment()
    cheaper = tel_m.cost_per_1k() < tel_b.cost_per_1k()
    assert better_slo or cheaper


def test_shared_weights_alone_is_deterministic(tables):
    """Same seed, same config => identical summaries with the shared
    ledger in the loop (the device model must not leak iteration order)."""
    tel1, _ = _run(tables, "flash-crowd", "memory", shared=True, hbm=HBM_MB)
    tel2, _ = _run(tables, "flash-crowd", "memory", shared=True, hbm=HBM_MB)
    assert tel1.summary() == tel2.summary()


# ---------------------------------------------------------------------------
# property walks: the refcounted shared-weights ledger
# ---------------------------------------------------------------------------
FUNCS = [("a", 300.0), ("b", 700.0), ("c", 150.0), ("d", 0.0)]


def _capped(dev, mb):
    return min(mb, dev.hbm_total_mb)


def _assert_shared_invariants(dev):
    """Beyond ``check()``: a shared function is charged once or not at
    all — never per container, never more than its capped footprint."""
    mb_of = dict(FUNCS)
    for func, ws in dev.weights.items():
        assert ws.mb in (0.0, _capped(dev, mb_of[func])), \
            f"{func} charged {ws.mb}, footprint {mb_of[func]}"
        assert ws.run_refs + ws.warm_refs > 0
    assert dev.hbm_used_mb == sum(w.mb for w in dev.weights.values())
    assert dev.hbm_used_mb <= dev.hbm_total_mb + 1e-6


def test_shared_ledger_random_walk_never_leaks():
    """600 random attach/detach/resize/prewarm/retire/gc steps through
    the public API: refcounts, slice and HBM ledgers stay consistent
    mid-walk, and a full drain returns the device to zero bytes."""
    rng = np.random.default_rng(7)
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=HBM_MB, shared_weights=True)
    now, live = 0.0, []
    for _ in range(600):
        now += float(rng.uniform(0.0, 50.0))
        op = int(rng.integers(6))
        f, mb = FUNCS[int(rng.integers(len(FUNCS)))]
        if op == 0:
            sl = int(rng.integers(1, 9))
            if dev.fits(sl, mb, f, now):
                alloc, tier = dev.start(f, sl, mb, now)   # must not raise
                assert tier in (HOT, WARM, COLD)
                live.append(alloc)
        elif op == 1 and live:
            a = live[int(rng.integers(len(live)))]
            dev.resize(a.aid, int(rng.integers(1, 17)))   # False ok, no drift
        elif op == 2 and live:
            a = live.pop(int(rng.integers(len(live))))
            dev.stop(a.aid, now + float(rng.uniform(100.0, 5000.0)))
        elif op == 3:
            dev.add_warm(f, now + float(rng.uniform(100.0, 5000.0)), mb, now)
        elif op == 4:
            entries = dev.warm_entries(f, now)
            if entries:
                dev.retire(f, entries[int(rng.integers(len(entries)))])
        else:
            dev._gc(now)
        dev.check()
        _assert_shared_invariants(dev)
    for a in live:
        dev.stop(a.aid, now + 100.0)
    assert dev.used_slices == 0
    dev._gc(now + 1e9)                    # all keep-alives expire
    assert dev.hbm_used_mb == 0.0 and not dev.weights


def test_shared_ledger_differential_walk_vs_private():
    """The same feasible-op sequence on a shared vs a private-copy
    device: shared residency never exceeds private residency (N copies
    collapse to one), and both ledgers obey their invariants."""
    rng = np.random.default_rng(11)
    shared = DeviceModel(vgpus=4, hbm_per_vgpu_mb=HBM_MB,
                         shared_weights=True)
    private = DeviceModel(vgpus=4, hbm_per_vgpu_mb=HBM_MB)
    now, live = 0.0, []
    for _ in range(300):
        now += float(rng.uniform(0.0, 40.0))
        op = int(rng.integers(4))
        f, mb = FUNCS[int(rng.integers(len(FUNCS)))]
        if op == 0:
            sl = int(rng.integers(1, 5))
            # drive both only when both admit, so the walks stay aligned
            if shared.fits(sl, mb, f, now) and private.fits(sl, mb, f, now):
                a1, _ = shared.start(f, sl, mb, now)
                a2, _ = private.start(f, sl, mb, now)
                live.append((a1, a2))
        elif op == 1 and live:
            (a1, a2) = live.pop(int(rng.integers(len(live))))
            exp = now + float(rng.uniform(100.0, 3000.0))
            shared.stop(a1.aid, exp)
            private.stop(a2.aid, exp)
        elif op == 2:
            exp = now + float(rng.uniform(100.0, 3000.0))
            shared.add_warm(f, exp, mb, now)
            private.add_warm(f, exp, mb, now)
        else:
            shared._gc(now)
            private._gc(now)
        shared.check()
        private.check()
        assert shared.hbm_used_mb <= private.hbm_used_mb + 1e-6, \
            "sharing made residency *larger* than per-container copies"


def test_shared_never_double_charges():
    dev = DeviceModel(vgpus=2, hbm_per_vgpu_mb=500.0, shared_weights=True)
    a1, t1 = dev.start("f", 1, 600.0, 0.0)
    a2, t2 = dev.start("f", 1, 600.0, 0.5)
    a3, t3 = dev.start("f", 1, 600.0, 1.0)
    assert (t1, t2, t3) == (COLD, COLD, COLD)
    assert dev.hbm_used_mb == 600.0               # one charge for three
    assert dev.stats.shared_hits == 2
    for a in (a1, a2, a3):
        dev.stop(a.aid, 1e5)
    assert dev.hbm_used_mb == 600.0               # still one shared copy
    assert dev.residency("f", 2.0) == HOT
    dev._gc(1e9)
    assert dev.hbm_used_mb == 0.0 and not dev.weights


def test_shared_demotion_flips_all_siblings_and_one_swap_restores():
    """Demotion under pressure moves the *function* to host (every idle
    sibling flips warm together); the next start pays one swap-in and
    re-promotes them all."""
    dev = DeviceModel(vgpus=2, hbm_per_vgpu_mb=500.0, shared_weights=True)
    a1, _ = dev.start("f", 1, 600.0, 0.0)
    a2, _ = dev.start("f", 1, 600.0, 0.1)
    dev.stop(a1.aid, 1e6)
    dev.stop(a2.aid, 1e6)
    ag, _ = dev.start("g", 1, 600.0, 1.0)         # forces f's set to host
    assert dev.stats.demotions == 1
    assert dev.residency("f", 1.0) == WARM
    assert all(c.tier == WARM for c in dev.pools["f"])
    dev.stop(ag.aid, 1e6)
    af, tf = dev.start("f", 1, 600.0, 2.0)
    assert tf == WARM and dev.stats.swap_ins == 1  # one swap for the set
    assert dev.residency("f", 2.0) == HOT          # sibling is hot again
    assert dev.swap_cost_ms("f", 600.0, 2.0, cold_ms=9e9) == 0.0


def test_shared_mode_packs_more_functions_than_private():
    """The pool-density win in one line: two 600-MB functions with two
    containers each fit a 1.5-GB device shared, but not as copies."""
    shared = DeviceModel(vgpus=3, hbm_per_vgpu_mb=500.0, shared_weights=True)
    private = DeviceModel(vgpus=3, hbm_per_vgpu_mb=500.0)
    for dev in (shared, private):
        for func in ("f", "g"):
            for _ in range(2):
                dev.add_warm(func, 1e6, 600.0, 0.0)
    assert shared.hbm_used_mb == 1200.0           # one copy per function
    assert all(c.tier == HOT for p in shared.pools.values() for c in p)
    # per-container copies: 2x600 + 600 fills the device, the 4th
    # container comes up warm (weights staged in host RAM)
    assert private.hbm_used_mb == 1200.0
    assert any(c.tier == WARM for p in private.pools.values() for c in p)
    n_hot = sum(c.tier == HOT for p in private.pools.values() for c in p)
    assert n_hot == 2 < 4                          # half the pool demote-bound


def test_shared_cold_boot_discounts_resident_weights():
    """A new container of a function whose weights a running peer keeps
    resident still cold-boots, but its weight load is a free mapping:
    the predicted (and billed) penalty deducts the weight-load
    component — so memory-aware placement prefers weight-dense invokers
    even when every keep-alive container of the function is busy."""
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=500.0, shared_weights=True)
    a1, _ = dev.start("f", 1, 600.0, 0.0)         # peer pins the weights
    assert dev.residency("f", 0.0) == COLD        # pool is empty
    assert dev.swap_cost_ms("f", 600.0, 0.0, cold_ms=5000.0) == \
        pytest.approx(5000.0 - swap_in_ms(600.0))
    # a private-copy device pays the full cold start in the same state
    pvt = DeviceModel(vgpus=4, hbm_per_vgpu_mb=500.0)
    pvt.start("f", 1, 600.0, 0.0)
    assert pvt.swap_cost_ms("f", 600.0, 0.0, cold_ms=5000.0) == 5000.0
    dev.stop(a1.aid, 1e6)


def test_shared_prewarm_repromotion_counts_swap_in():
    """Re-loading a demoted shared set through the pre-warm path flips
    every WARM sibling hot at once: the H2D copy is counted as a
    swap-in (no latency — it is a background prefetch), instead of
    silently inflating the swap-avoidance numbers."""
    dev = DeviceModel(vgpus=2, hbm_per_vgpu_mb=500.0, shared_weights=True)
    a1, _ = dev.start("f", 1, 600.0, 0.0)
    dev.stop(a1.aid, 1e6)
    ag, _ = dev.start("g", 1, 600.0, 1.0)         # demotes f's set
    assert dev.residency("f", 1.0) == WARM
    dev.stop(ag.aid, 2.0 + 1e-9)
    dev._gc(3.0)                                  # g's keep-alive expires
    dev.add_warm("f", 1e6, 600.0, 3.0)            # prefetch re-loads f
    assert dev.residency("f", 3.0) == HOT
    assert all(c.tier == HOT for c in dev.pools["f"])
    assert dev.stats.swap_ins == 1                # the reload was counted
    assert dev.stats.swap_in_ms == pytest.approx(swap_in_ms(600.0))


def test_residency_and_swap_cost_queries():
    dev = DeviceModel(vgpus=1, hbm_per_vgpu_mb=1000.0)
    assert dev.residency("f", 0.0) == COLD
    assert dev.swap_cost_ms("f", 400.0, 0.0, cold_ms=1234.0) == 1234.0
    assert dev.swap_cost_ms("f", 400.0, 0.0) == swap_in_ms(400.0)  # lower bound
    dev.add_warm("f", 100.0, 400.0, 0.0)
    assert dev.residency("f", 1.0) == HOT
    assert dev.swap_cost_ms("f", 400.0, 1.0, cold_ms=1234.0) == 0.0
    assert dev.residency("f", 200.0) == COLD      # keep-alive expired
    assert tier_penalty_ms(WARM, 400.0, 1234.0) == swap_in_ms(400.0)


# ---------------------------------------------------------------------------
# planner pricing: esg_1q penalties vs the brute-force oracle
# ---------------------------------------------------------------------------
def test_esg_1q_penalties_match_brute_force(tables):
    tbls = [tables["super_resolution"], tables["classification"]]
    pens = [swap_in_ms(170.0), swap_in_ms(230.0)]
    slo = 800.0
    fast = esg_1q(tbls, slo, k=5, penalties_ms=pens)
    ref = brute_force(tbls, slo, k=5, penalties_ms=pens)
    assert fast and [r.configs for r in fast] == [r.configs for r in ref]
    assert fast[0].est_time_ms == pytest.approx(ref[0].est_time_ms)
    assert fast[0].est_job_cost == pytest.approx(ref[0].est_job_cost)


def test_esg_1q_zero_penalties_identical(tables):
    tbls = [tables["segmentation"], tables["deblur"]]
    a = esg_1q(tbls, 2000.0, k=5)
    b = esg_1q(tbls, 2000.0, k=5, penalties_ms=[0.0, 0.0])
    assert a == b
    with pytest.raises(ValueError):
        esg_1q(tbls, 2000.0, penalties_ms=[1.0])   # length mismatch


def test_with_penalty_shifts_both_blades(tables):
    t = tables["depth"]
    p = t.with_penalty(50.0)
    assert np.allclose(p.times, t.times + 50.0)
    assert np.all(p.job_costs > t.job_costs)       # every config pays rent
    assert np.all(np.diff(p.times) >= 0)           # still sorted by time
    assert t.with_penalty(0.0) is t


# ---------------------------------------------------------------------------
# golden regression: one fig6 cell pinned to a checked-in fixture
# ---------------------------------------------------------------------------
GOLDEN_KEYS = ["scheduler", "setting", "scenario", "completed",
               "slo_hit_rate", "total_cost", "mean_latency_ms",
               "p95_latency_ms", "cold_starts", "remote_transfers",
               "hot_hits", "warm_hits", "swap_ins", "demotions",
               "shared_hits"]


def test_fig6_mmpp_row_matches_golden_fixture():
    """The fig6 pipeline (benchmarks/common.run_setting) for the mmpp
    scenario under the default ESG policy must reproduce the checked-in
    numbers exactly — refactors of ``_place``/the device model cannot
    silently shift legacy results.  (``count_overhead=False`` keeps the
    run bit-deterministic: measured wall time stays out of latency.)"""
    sys.path.insert(0, str(HERE.parent / "benchmarks"))
    try:
        import common
    finally:
        sys.path.pop(0)
    r = common.run_setting("ESG", "moderate-normal", n=40, seed=0,
                           scenario="mmpp", count_overhead=False)
    got = {k: r[k] for k in GOLDEN_KEYS}
    fixture = HERE / "fixtures" / "fig6_mmpp_golden.json"
    want = json.loads(fixture.read_text())
    assert got == want, (
        f"fig6 mmpp golden row drifted.\n got: {got}\nwant: {want}\n"
        f"If the change is intentional, regenerate {fixture}.")


# ---------------------------------------------------------------------------
# trace CSV robustness (read_csv bugfix)
# ---------------------------------------------------------------------------
def test_trace_csv_skips_blank_and_trailing_lines(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("t_ms,app\n10,f\n\n   \n20,g\n,\n30,h\n\n\n")
    assert TraceReplayScenario.read_csv(str(p)) == \
        [(10.0, "f"), (20.0, "g"), (30.0, "h")]


def test_trace_csv_errors_name_file_and_line(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("t_ms,app\n10,f\n20\n")           # row missing 'app'
    with pytest.raises(ValueError, match=r"trace\.csv line 3.*'app'"):
        TraceReplayScenario.read_csv(str(p))
    p.write_text("t_ms,app\nnot-a-number,f\n")
    with pytest.raises(ValueError, match=r"trace\.csv line 2.*t_ms"):
        TraceReplayScenario.read_csv(str(p))
    p.write_text("time,function\n1,f\n")           # bad header
    with pytest.raises(ValueError, match="needs a 't_ms,app' header"):
        TraceReplayScenario.read_csv(str(p))


def test_trace_csv_ignores_extra_columns(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("t_ms,app,region\n5,f,us\n7,g,eu\n")
    assert TraceReplayScenario.read_csv(str(p)) == [(5.0, "f"), (7.0, "g")]
